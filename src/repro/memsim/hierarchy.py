"""Hierarchy orchestration: shared demand profile + per-prefetcher runs.

Logical time convention: every event carries a position on the *full* access
trace; merged demand/prefetch ordering doubles positions so a prefetch
triggered by access ``p`` lands at ``2p+1`` — after its trigger, before the
next demand access at ``2(p+1)``.

All per-event output arrays are kept so metrics can be evaluated over a
position window (``eval_from_pos``): the paper evaluates BFS/BellmanFord on
the *second* (post-graph-change) run only, with caches warm from run 1.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.memsim.config import HierarchyConfig
from repro.memsim.engine import (
    CacheState,
    cache_pass,
    cache_pass_batch,
    current_engine,
    init_state,
)
from repro.memsim.fused import fused_cache_pass, fused_cache_pass_batch
from repro.memsim.scan_cache import classify_prefetch_events


def _stage(name: str):
    """Per-level stage-timer hook (``cache_pass[l1|l2|llc|fused]``).

    Imported lazily: :mod:`repro.core.exec.timers` is dependency-free, but
    reaching it imports the ``repro.core`` package, which imports this
    module back — fine at call time, a cycle at import time.
    """
    from repro.core.exec.timers import stage

    return stage(name)


def _count_launch(batched: int = 0) -> None:
    """Metrics counters for fused-pass dispatches (no-op when obs is off):
    ``fused.launches`` counts scan launches, ``fused.batched_streams`` the
    streams a batched launch covered — together they make the
    three-passes→one-launch collapse visible in the telemetry snapshot."""
    from repro.core.obs.spans import inc

    inc("fused.launches")
    if batched:
        inc("fused.batched_streams", batched)


def _demand_levels(cfg: HierarchyConfig):
    return (
        (cfg.l1.sets, cfg.l1.ways),
        (cfg.l2.sets, cfg.l2.ways),
        (cfg.llc.sets, cfg.llc.ways),
    )


@dataclasses.dataclass
class DemandProfile:
    """Baseline (no-prefetch) simulation of one full trace."""

    blocks: np.ndarray  # full trace line ids
    iter_id: np.ndarray  # full trace iteration (epoch) ids
    l1_hit: np.ndarray  # (N,) bool
    # L1-miss substream (these are the L2 accesses):
    l2_pos: np.ndarray  # positions into the full trace
    l2_blocks: np.ndarray
    l2_iter: np.ndarray
    l2_hit: np.ndarray  # baseline L2 hit mask over substream
    llc_hit: np.ndarray  # baseline LLC hit mask over the L2-miss substream
    cfg: HierarchyConfig

    @property
    def num_accesses(self) -> int:
        return len(self.blocks)

    @property
    def l2_miss_pos(self) -> np.ndarray:
        return self.l2_pos[~self.l2_hit]

    @property
    def l2_miss_blocks(self) -> np.ndarray:
        return self.l2_blocks[~self.l2_hit]

    @property
    def l2_miss_iter(self) -> np.ndarray:
        return self.l2_iter[~self.l2_hit]

    def baseline_counts(self, from_pos: int = 0) -> dict:
        # l2_pos / l2_miss_pos are sorted, so window counts are searchsorteds.
        i_l2 = int(np.searchsorted(self.l2_pos, from_pos))
        mp = self.l2_miss_pos
        i_llc = int(np.searchsorted(mp, from_pos))
        dram = int((~self.llc_hit[i_llc:]).sum())
        return dict(
            accesses=self.num_accesses - from_pos,
            l1_miss=len(self.l2_pos) - i_l2,
            l2_miss=int((~self.l2_hit[i_l2:]).sum()),
            llc_miss=dram,
            dram=dram,
        )


@dataclasses.dataclass
class DemandState:
    """Carried hierarchy state for chunked (sharded) demand simulation.

    Bundles the canonical per-level :class:`CacheState` carries plus the
    global position of the next access, so a sequence of
    :func:`simulate_demand` calls over trace chunks produces profiles whose
    concatenation is bit-identical to one whole-trace call — the shard-seam
    contract the streaming scorer builds on.
    """

    l1: CacheState
    l2: CacheState
    llc: CacheState
    pos_offset: int = 0


def demand_init_state(cfg: HierarchyConfig) -> DemandState:
    """Cold-cache carry (equivalent to passing ``state=None``)."""
    return DemandState(
        l1=init_state(cfg.l1.sets, cfg.l1.ways),
        l2=init_state(cfg.l2.sets, cfg.l2.ways),
        llc=init_state(cfg.llc.sets, cfg.llc.ways),
        pos_offset=0,
    )


def simulate_demand(
    blocks: np.ndarray,
    iter_id: np.ndarray,
    cfg: HierarchyConfig,
    state: DemandState | None = None,
    return_state: bool = False,
):
    """Baseline demand simulation; optionally resuming from / yielding a
    :class:`DemandState` carry for chunked traces.  With a carry, ``l2_pos``
    is expressed in *global* trace positions (``state.pos_offset`` +
    chunk-local index), keeping windowed metrics chunk-invariant."""
    offset = 0
    if state is not None:
        offset = state.pos_offset
    if current_engine() == "fused":
        return _simulate_demand_fused(blocks, iter_id, cfg, state, return_state)
    with _stage("cache_pass[l1]"):
        l1_hit = cache_pass(
            blocks,
            cfg.l1.sets,
            cfg.l1.ways,
            state=state.l1 if state is not None else None,
            return_state=return_state,
        )
        if return_state:
            l1_hit, l1_state = l1_hit
    l2_pos = np.flatnonzero(~l1_hit).astype(np.int64) + offset
    l2_blocks = blocks[l2_pos - offset]
    l2_iter = iter_id[l2_pos - offset]
    with _stage("cache_pass[l2]"):
        l2_hit = cache_pass(
            l2_blocks,
            cfg.l2.sets,
            cfg.l2.ways,
            state=state.l2 if state is not None else None,
            return_state=return_state,
        )
        if return_state:
            l2_hit, l2_state = l2_hit
    llc_in = l2_blocks[~l2_hit]
    with _stage("cache_pass[llc]"):
        llc_hit = cache_pass(
            llc_in,
            cfg.llc.sets,
            cfg.llc.ways,
            state=state.llc if state is not None else None,
            return_state=return_state,
        )
        if return_state:
            llc_hit, llc_state = llc_hit
    profile = DemandProfile(
        blocks=blocks,
        iter_id=iter_id,
        l1_hit=l1_hit,
        l2_pos=l2_pos,
        l2_blocks=l2_blocks,
        l2_iter=l2_iter,
        l2_hit=l2_hit,
        llc_hit=llc_hit,
        cfg=cfg,
    )
    if not return_state:
        return profile
    next_state = DemandState(
        l1=l1_state, l2=l2_state, llc=llc_state, pos_offset=offset + len(blocks)
    )
    return profile, next_state


def _profile_from_levels(
    blocks: np.ndarray,
    iter_id: np.ndarray,
    cfg: HierarchyConfig,
    lvl: np.ndarray,
    offset: int,
) -> DemandProfile:
    """Unpack a fused pass's hit-level array (0=L1 hit, 1=L2, 2=LLC,
    3=DRAM) into the cascaded per-level masks of :class:`DemandProfile` —
    each level's mask covers exactly the miss substream of the level
    above, identical to the per-level path by set independence."""
    l1_hit = lvl == 0
    l2_pos = np.flatnonzero(~l1_hit).astype(np.int64) + offset
    l2_lvl = lvl[~l1_hit]
    l2_hit = l2_lvl == 1
    return DemandProfile(
        blocks=blocks,
        iter_id=iter_id,
        l1_hit=l1_hit,
        l2_pos=l2_pos,
        l2_blocks=blocks[l2_pos - offset],
        l2_iter=iter_id[l2_pos - offset],
        l2_hit=l2_hit,
        llc_hit=l2_lvl[~l2_hit] == 2,
        cfg=cfg,
    )


def _simulate_demand_fused(
    blocks: np.ndarray,
    iter_id: np.ndarray,
    cfg: HierarchyConfig,
    state: DemandState | None,
    return_state: bool,
):
    """One carried L1→L2→LLC scan instead of three passes with host-side
    miss compaction between them (the ``fused`` engine's demand path)."""
    offset = state.pos_offset if state is not None else 0
    states = [state.l1, state.l2, state.llc] if state is not None else None
    with _stage("cache_pass[fused]"):
        res = fused_cache_pass(
            blocks, _demand_levels(cfg), states, return_states=return_state
        )
        _count_launch()
    lvl = res[0] if return_state else res
    profile = _profile_from_levels(blocks, iter_id, cfg, lvl, offset)
    if not return_state:
        return profile
    l1_state, l2_state, llc_state = res[1]
    return profile, DemandState(
        l1=l1_state, l2=l2_state, llc=llc_state, pos_offset=offset + len(blocks)
    )


def simulate_demand_batch(
    items: list,
    cfg: HierarchyConfig,
) -> list:
    """Demand-simulate same-hierarchy traces as one batched dispatch.

    ``items`` is a list of ``(blocks, iter_id)`` pairs (e.g. the seed
    replicas of one bench cell).  Under the ``fused`` engine the traces
    pad to a common bucket and run as a single vmapped scan when the
    cost-based plan chooser picks the carried scan for every member
    (run-collapse shrank each bucket); otherwise they loop through the
    bit-identical per-stream plan.  Other engines loop
    :func:`simulate_demand`.  Results are bit-identical either way.
    """
    if current_engine() != "fused":
        return [simulate_demand(b, it, cfg) for b, it in items]
    with _stage("cache_pass[fused]"):
        lvls = fused_cache_pass_batch(
            [b for b, _ in items], _demand_levels(cfg)
        )
        _count_launch(batched=len(items))
    return [
        _profile_from_levels(b, it, cfg, lvl, 0)
        for (b, it), lvl in zip(items, lvls)
    ]


@dataclasses.dataclass
class PrefetchOutcome:
    """Per-prefetcher simulation result over one trace (per-event arrays)."""

    pf_pos: np.ndarray  # issue positions (full-trace units)
    pf_issuer: np.ndarray  # (n_pf,) int8 issuer id (composite prefetching)
    pf_redundant: np.ndarray  # (n_pf,) bool: block already resident
    pf_no_future: np.ndarray  # (n_pf,) bool: never demanded after issue
    pf_llc_in_dram: np.ndarray  # over pf L2-misses: went to DRAM
    pf_llc_in_pos: np.ndarray  # their positions
    demand_l2_hit: np.ndarray  # (n_demand,) with prefetcher
    demand_useful: np.ndarray  # (n_demand,) demand hit on pf line
    demand_late: np.ndarray  # (n_demand,) useful but still in flight
    demand_fill_issuer: np.ndarray  # (n_demand,) issuer of the useful fill, -1
    demand_llc_hit: np.ndarray  # over demand L2 misses (with prefetcher)
    evicted_early_total: int
    pf_early: np.ndarray  # (n_pf,) prefetch fill evicted before reuse
    metadata_bytes: int = 0
    # LLC-input stream (only with ``keep_llc_stream=True``): the exact
    # event sequence the private LLC pass consumed, in simulation order —
    # block ids, doubled positions (2p demand / 2p+1 prefetch), and the
    # is-prefetch flag.  The multi-tenant serving layer re-plays these
    # events through one *shared* LLC (repro.memsim.shared_llc) and patches
    # ``demand_llc_hit``/``pf_llc_in_dram`` with the contended hit masks.
    # Default None keeps artifact round-trips and pickling unchanged.
    llc_in_blocks: np.ndarray | None = None
    llc_in_pos2: np.ndarray | None = None
    llc_in_is_pf: np.ndarray | None = None

    @property
    def issued(self) -> int:
        return len(self.pf_pos)


def simulate_with_prefetch(
    profile: DemandProfile,
    pf_blocks: np.ndarray,
    pf_pos: np.ndarray,
    pf_issuer: np.ndarray | None = None,
    metadata_bytes: int = 0,
    keep_llc_stream: bool = False,
) -> PrefetchOutcome:
    """Re-simulate L2+LLC with a (possibly multi-issuer) prefetch stream.

    ``keep_llc_stream=True`` additionally stashes the LLC-input event
    stream (blocks, doubled positions, is-prefetch flags) on the outcome
    so a shared-LLC pass can re-simulate it under multi-tenant contention.
    """
    cfg = profile.cfg
    nd = len(profile.l2_blocks)
    npf = len(pf_blocks)
    if npf == 0:
        d_miss = ~profile.l2_hit
        return PrefetchOutcome(
            pf_pos=np.zeros(0, dtype=np.int64),
            pf_issuer=np.zeros(0, dtype=np.int8),
            pf_redundant=np.zeros(0, dtype=bool),
            pf_no_future=np.zeros(0, dtype=bool),
            pf_llc_in_dram=np.zeros(0, dtype=bool),
            pf_llc_in_pos=np.zeros(0, dtype=np.int64),
            demand_l2_hit=profile.l2_hit.copy(),
            demand_useful=np.zeros(nd, dtype=bool),
            demand_late=np.zeros(nd, dtype=bool),
            demand_fill_issuer=np.full(nd, -1, dtype=np.int8),
            demand_llc_hit=profile.llc_hit.copy(),
            evicted_early_total=0,
            pf_early=np.zeros(0, dtype=bool),
            metadata_bytes=metadata_bytes,
            llc_in_blocks=profile.l2_blocks[d_miss] if keep_llc_stream else None,
            llc_in_pos2=2 * profile.l2_pos[d_miss] if keep_llc_stream else None,
            llc_in_is_pf=np.zeros(int(d_miss.sum()), dtype=bool)
            if keep_llc_stream
            else None,
        )

    merged = _merge_prefetch_stream(profile, pf_blocks, pf_pos, pf_issuer)
    mblocks_s = merged["mblocks_s"]
    # Scoring a single stream runs the per-level cascade under every
    # engine: the L2 substream has no L1-filterable runs to collapse, so
    # a carried L2→LLC scan would add gather/scatter cost per step
    # without removing any.  The fused engine's scoring win is *batching*
    # — see simulate_with_prefetch_batch.
    with _stage("cache_pass[l2]"):
        hit = cache_pass(mblocks_s, cfg.l2.sets, cfg.l2.ways)
    # LLC sees every L2 miss (demand or prefetch) in order.
    with _stage("cache_pass[llc]"):
        llc_hit = cache_pass(
            mblocks_s[~hit], cfg.llc.sets, cfg.llc.ways
        )
    return _finish_prefetch_outcome(
        profile, merged, hit, llc_hit, metadata_bytes, keep_llc_stream
    )


def simulate_with_prefetch_batch(
    profile: DemandProfile,
    streams: list,
    metadata_bytes: list | None = None,
    keep_llc_stream: bool = False,
) -> list:
    """Score several prefetch streams against one profile in one dispatch.

    ``streams`` is a list of ``(pf_blocks, pf_pos, pf_issuer)`` triples
    (``pf_issuer`` may be None) — typically one per prefetcher family of a
    workload.  Under the ``fused`` engine the merged L2 streams pad to a
    common bucket and run as one vmapped set-parallel launch per level
    (:func:`repro.memsim.engine.cache_pass_batch`) — the family's
    ``2 × n_prefetchers`` scoring launches collapse to two; other engines
    (and empty streams) loop :func:`simulate_with_prefetch`.  Outcomes
    are bit-identical to the loop either way.
    """
    meta = metadata_bytes if metadata_bytes is not None else [0] * len(streams)
    if current_engine() != "fused" or any(len(s[0]) == 0 for s in streams):
        return [
            simulate_with_prefetch(
                profile, b, p, issuer, m, keep_llc_stream=keep_llc_stream
            )
            for (b, p, issuer), m in zip(streams, meta)
        ]
    cfg = profile.cfg
    merged = [
        _merge_prefetch_stream(profile, b, p, issuer) for b, p, issuer in streams
    ]
    with _stage("cache_pass[l2]"):
        l2_hits = cache_pass_batch(
            [m["mblocks_s"] for m in merged], cfg.l2.sets, cfg.l2.ways
        )
        _count_launch(batched=len(streams))
    with _stage("cache_pass[llc]"):
        llc_hits = cache_pass_batch(
            [m["mblocks_s"][~h] for m, h in zip(merged, l2_hits)],
            cfg.llc.sets,
            cfg.llc.ways,
        )
        _count_launch(batched=len(streams))
    return [
        _finish_prefetch_outcome(profile, m, h, lh, mb, keep_llc_stream)
        for m, h, lh, mb in zip(merged, l2_hits, llc_hits, meta)
    ]


def _merge_prefetch_stream(
    profile: DemandProfile,
    pf_blocks: np.ndarray,
    pf_pos: np.ndarray,
    pf_issuer: np.ndarray | None,
) -> dict:
    """Interleave a prefetch stream into the demand L2 substream.

    Demand events land at doubled positions ``2p``, prefetches at
    ``2p+1``.  Both substreams are position-sorted, so the merge is a
    single searchsorted instead of a full argsort of the concatenation.
    """
    nd = len(profile.l2_blocks)
    npf = len(pf_blocks)
    pf_blocks = np.asarray(pf_blocks, dtype=np.int64)
    pf_pos = np.asarray(pf_pos, dtype=np.int64)
    if pf_issuer is None:
        pf_issuer = np.zeros(npf, dtype=np.int8)
    pf_issuer = np.asarray(pf_issuer, dtype=np.int8)
    if npf > 1 and np.any(pf_pos[1:] < pf_pos[:-1]):
        o = np.argsort(pf_pos, kind="stable")
        pf_pos, pf_blocks, pf_issuer = pf_pos[o], pf_blocks[o], pf_issuer[o]

    total = nd + npf
    pf_slots = np.searchsorted(2 * profile.l2_pos, 2 * pf_pos + 1) + np.arange(npf)
    demand_slots = np.ones(total, dtype=bool)
    demand_slots[pf_slots] = False
    demand_slots = np.flatnonzero(demand_slots)
    mpos_s = np.empty(total, dtype=np.int64)
    mblocks_s = np.empty(total, dtype=np.int64)
    m_is_pf_s = np.zeros(total, dtype=bool)
    mpos_s[demand_slots] = 2 * profile.l2_pos
    mpos_s[pf_slots] = 2 * pf_pos + 1
    mblocks_s[demand_slots] = profile.l2_blocks
    mblocks_s[pf_slots] = pf_blocks
    m_is_pf_s[pf_slots] = True

    m_issuer = np.full(total, -1, dtype=np.int8)
    m_issuer[pf_slots] = pf_issuer
    return dict(
        pf_blocks=pf_blocks,
        pf_pos=pf_pos,
        pf_issuer=pf_issuer,
        pf_slots=pf_slots,
        demand_slots=demand_slots,
        mpos_s=mpos_s,
        mblocks_s=mblocks_s,
        m_is_pf_s=m_is_pf_s,
        m_issuer=m_issuer,
    )


def _finish_prefetch_outcome(
    profile: DemandProfile,
    merged: dict,
    hit: np.ndarray,
    llc_hit: np.ndarray,
    metadata_bytes: int,
    keep_llc_stream: bool,
) -> PrefetchOutcome:
    """Classify + unmerge one scored stream back into a
    :class:`PrefetchOutcome` (``hit`` over the merged stream, ``llc_hit``
    over its L2-miss substream — however the passes were dispatched)."""
    cfg = profile.cfg
    mblocks_s = merged["mblocks_s"]
    mpos_s = merged["mpos_s"]
    m_is_pf_s = merged["m_is_pf_s"]
    pf_slots = merged["pf_slots"]
    demand_slots = merged["demand_slots"]
    pf_blocks, pf_pos = merged["pf_blocks"], merged["pf_pos"]

    useful, late, redundant, early, fill_origin = classify_prefetch_events(
        mblocks_s, m_is_pf_s, mpos_s, hit, 2 * cfg.pf_fill_window
    )
    llc_sel = ~hit
    llc_is_pf = m_is_pf_s[llc_sel]
    llc_pos = mpos_s[llc_sel] // 2

    # Unmerge.
    demand_l2_hit = hit[demand_slots]
    demand_useful = useful[demand_slots]
    demand_late = late[demand_slots]
    pf_redundant = redundant[pf_slots]
    pf_early = early[pf_slots]
    d_fill = fill_origin[demand_slots]
    demand_fill_issuer = np.where(
        d_fill >= 0, merged["m_issuer"][np.maximum(d_fill, 0)], -1
    ).astype(np.int8)

    # Demand LLC hits over demand L2 misses, in demand order: the demand
    # events within the LLC stream appear in merged order == pos order,
    # which equals demand-substream order (stable sort on pos).
    demand_llc_hit = llc_hit[~llc_is_pf]

    pf_no_future = _no_future_demand(
        pf_blocks, pf_pos, profile.l2_miss_blocks, profile.l2_miss_pos
    )

    return PrefetchOutcome(
        pf_pos=pf_pos,
        pf_issuer=merged["pf_issuer"],
        pf_redundant=pf_redundant,
        pf_no_future=pf_no_future,
        pf_llc_in_dram=(~llc_hit)[llc_is_pf],
        pf_llc_in_pos=llc_pos[llc_is_pf],
        demand_l2_hit=demand_l2_hit,
        demand_useful=demand_useful,
        demand_late=demand_late,
        demand_fill_issuer=demand_fill_issuer,
        demand_llc_hit=demand_llc_hit,
        evicted_early_total=int(early.sum()),
        pf_early=pf_early,
        metadata_bytes=metadata_bytes,
        llc_in_blocks=mblocks_s[llc_sel] if keep_llc_stream else None,
        llc_in_pos2=mpos_s[llc_sel] if keep_llc_stream else None,
        llc_in_is_pf=llc_is_pf if keep_llc_stream else None,
    )


def _no_future_demand(
    pf_blocks: np.ndarray,
    pf_pos: np.ndarray,
    demand_blocks: np.ndarray,
    demand_pos: np.ndarray,
) -> np.ndarray:
    """Per-prefetch flag: block never appears in future baseline L2 misses."""
    if len(pf_blocks) == 0:
        return np.zeros(0, dtype=bool)
    if len(demand_blocks) == 0:
        return np.ones(len(pf_blocks), dtype=bool)
    dkey_sort = (demand_blocks.astype(np.int64) << np.int64(31)) | demand_pos
    order = np.argsort(dkey_sort)
    db = demand_blocks[order]
    dp = demand_pos[order]
    BIG = np.int64(1) << 40
    dkey = db.astype(np.int64) * BIG + dp
    pkey = pf_blocks.astype(np.int64) * BIG + pf_pos
    idx = np.searchsorted(dkey, pkey, side="right")
    safe = np.minimum(idx, len(db) - 1)
    has_future = (idx < len(dkey)) & (db[safe] == pf_blocks)
    return ~has_future
