"""Trace-driven memory-hierarchy simulator.

Methodology (two-pass functional simulation, DESIGN.md §2.1):

  pass L1      : full access stream -> L1 hit mask (prefetchers never fill
                 L1, so this pass is shared by baseline and every prefetcher)
  pass L2-base : L1-miss substream  -> baseline L2 miss stream (recording
                 ground truth + coverage denominator)
  pass L2-pf   : merged demand + prefetch stream with per-line pf bits and
                 fill-time tracking -> useful/late/evicted-early counts
  pass LLC     : L2-miss substream  -> off-chip (DRAM) access counts

Every pass runs through :func:`repro.memsim.engine.cache_pass` — by default
the ``fused`` engine (:mod:`repro.memsim.fused`), which carries all the
levels a simulation touches in one set-parallel scan and emits per-access
hit levels directly, collapsing the passes above into a single launch.
The per-level set-parallel engine (sets simulated concurrently, scan
length ~N/sets) remains as ``set_parallel``, and the original serial
``lax.scan`` is the bit-identical ``reference`` oracle
(``REPRO_CACHE_ENGINE=reference``).

Timing is a calibrated miss-penalty IPC model with measured MLP overlap
(:mod:`repro.memsim.timing`), reproducing the paper's *relative* speedups.
"""
from repro.memsim.config import CacheLevelConfig, HierarchyConfig, PAPER, SCALED
from repro.memsim.engine import (
    ENGINES,
    cache_pass,
    current_engine,
    set_engine,
    use_engine,
)
from repro.memsim.fused import fused_cache_pass, fused_cache_pass_batch
from repro.memsim.scan_cache import classify_prefetch_events
from repro.memsim.hierarchy import (
    DemandProfile,
    PrefetchOutcome,
    simulate_demand,
    simulate_demand_batch,
    simulate_with_prefetch,
    simulate_with_prefetch_batch,
)
from repro.memsim.timing import TimingModel, estimate_cycles
from repro.memsim.metrics import PrefetchMetrics, evaluate, geomean, summarize_epochs

__all__ = [
    "CacheLevelConfig",
    "ENGINES",
    "HierarchyConfig",
    "PAPER",
    "SCALED",
    "cache_pass",
    "classify_prefetch_events",
    "current_engine",
    "fused_cache_pass",
    "fused_cache_pass_batch",
    "set_engine",
    "use_engine",
    "DemandProfile",
    "PrefetchOutcome",
    "simulate_demand",
    "simulate_demand_batch",
    "simulate_with_prefetch",
    "simulate_with_prefetch_batch",
    "TimingModel",
    "estimate_cycles",
    "PrefetchMetrics",
    "evaluate",
    "geomean",
    "summarize_epochs",
]
