"""Bounded-memory streaming scoring over chunked (sharded) traces.

The whole-trace scorer (:func:`repro.memsim.hierarchy.simulate_with_prefetch`
+ :func:`repro.memsim.metrics.evaluate`) materializes every per-event array
for the full run.  This module re-expresses that pipeline as a sequence of
per-chunk passes whose peak memory is O(chunk) in the trace length (working
tables are proportional to the number of *distinct* blocks touched — the
graph footprint — never to the stream length):

- :class:`SpillFile` — raw int64 column spills for position streams that a
  later stage must re-read (MLP measurement, the AMC training views).
- :func:`spilled_mlp` — :func:`repro.memsim.timing.measure_mlp` replicated
  over a spilled position stream, bit-identical including its subsample
  stride and the float64 mean.
- :class:`ClassifyCarry` + :func:`classify_chunk` — the chunked counterpart
  of :func:`repro.memsim.scan_cache.classify_prefetch_events`: a per-block
  carry table (last fill position/issuer, the all-prefetx-since-fill tail
  bit, a pending early-eviction fill) makes per-chunk classification exactly
  equal to whole-trace classification.
- :class:`BlockPosTable` — per-block last-position table (the streaming
  form of :func:`repro.memsim.hierarchy._no_future_demand`).
- :class:`CompositeRunScorer` — one composite run (demand + prefetch merge,
  L2 + LLC passes with carried :class:`~repro.memsim.engine.CacheState`,
  classification, windowed count accumulation, MLP spills) fed chunk by
  chunk; ``finalize`` reproduces ``metrics._outcome_cycles`` exactly.

Every count and float produced here is asserted bit-identical to the
unsharded scorer in ``tests/test_sharded.py``.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.memsim.config import HierarchyConfig
from repro.memsim.engine import CacheState, cache_pass, init_state
from repro.memsim.timing import TimingModel, estimate_cycles


def _stage(name: str):
    from repro.core.exec.timers import stage  # lazy: import cycle at load

    return stage(name)


# ------------------------------------------------------------------ spills


class SpillFile:
    """Append-only on-disk store of int64 rows with ``cols`` columns.

    Rows are written raw (native-endian int64, row-major), so a spill of a
    position stream costs 8 bytes/column/row and reads back in fixed-size
    chunks without ever materializing the whole stream.
    """

    def __init__(self, path, cols: int = 1):
        self.path = Path(path)
        self.cols = cols
        self.rows = 0
        self._fh = open(self.path, "wb")

    def append(self, *columns: np.ndarray) -> None:
        if len(columns) != self.cols:
            raise ValueError(f"expected {self.cols} columns, got {len(columns)}")
        n = len(columns[0])
        if n == 0:
            return
        if self.cols == 1:
            out = np.ascontiguousarray(columns[0], dtype=np.int64)
        else:
            out = np.empty((n, self.cols), dtype=np.int64)
            for j, c in enumerate(columns):
                if len(c) != n:
                    raise ValueError("ragged spill append")
                out[:, j] = c
        out.tofile(self._fh)
        self.rows += n

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def flush(self) -> None:
        if self._fh is not None:
            self._fh.flush()

    def groups(self, counts: Sequence[int]) -> Iterator[Tuple[np.ndarray, ...]]:
        """Yield column tuples of exactly ``counts[i]`` rows each, in write
        order — the per-chunk replay reader (``sum(counts) <= rows``)."""
        self.flush()
        with open(self.path, "rb") as fh:
            for c in counts:
                flat = np.fromfile(fh, dtype=np.int64, count=int(c) * self.cols)
                mat = flat.reshape(int(c), self.cols)
                yield tuple(mat[:, j].copy() for j in range(self.cols))

    def chunks(self, rows: int = 1 << 20) -> Iterator:
        """Yield column tuples (or bare arrays when ``cols == 1``) of up to
        ``rows`` rows each, in write order.  Flushes the writer first."""
        self.flush()
        done = 0
        with open(self.path, "rb") as fh:
            while done < self.rows:
                take = min(rows, self.rows - done)
                flat = np.fromfile(fh, dtype=np.int64, count=take * self.cols)
                done += take
                if self.cols == 1:
                    yield flat
                else:
                    mat = flat.reshape(take, self.cols)
                    yield tuple(mat[:, j].copy() for j in range(self.cols))


def spilled_mlp(spill: SpillFile, window: int, cap: float, rows: int = 1 << 20) -> float:
    """:func:`repro.memsim.timing.measure_mlp` over a spilled position
    stream (already ascending, distinct — both true of every miss-position
    stream here), bit-identical to the in-memory version.

    A sample taken at global index ``i`` counts entries in ``[v, v+window]``;
    entries in earlier chunks are all ``< v`` and entries in later chunks all
    ``> chunk[-1]``, so a sample finalizes as soon as a chunk tail exceeds
    ``v + window`` — unfinalized samples carry their partial counts forward.
    """
    n = spill.rows
    if n < 2:
        return 1.0
    stride = max(n // 1_000_000, 1)
    total = 0
    nsamp = 0
    pend_v = np.zeros(0, dtype=np.int64)
    pend_c = np.zeros(0, dtype=np.int64)
    gidx = 0
    for arr in spill.chunks(rows):
        if len(arr) == 0:
            continue
        if len(pend_v):
            pend_c = pend_c + np.searchsorted(arr, pend_v + window, side="right")
            fin = arr[-1] > pend_v + window
            total += int(pend_c[fin].sum())
            nsamp += int(fin.sum())
            pend_v, pend_c = pend_v[~fin], pend_c[~fin]
        first = (-gidx) % stride
        j = np.arange(first, len(arr), stride, dtype=np.int64)
        if len(j):
            v = arr[j]
            cnt = np.searchsorted(arr, v + window, side="right") - j
            fin = arr[-1] > v + window
            total += int(cnt[fin].sum())
            nsamp += int(fin.sum())
            pend_v = np.concatenate([pend_v, v[~fin]])
            pend_c = np.concatenate([pend_c, cnt[~fin]])
        gidx += len(arr)
    total += int(pend_c.sum())
    nsamp += len(pend_v)
    # measure_mlp's .mean(): pairwise float64 summation of small ints is
    # exact (counts <= window+1 and totals < 2**53), so sum/len is the mean.
    mean = np.float64(total) / np.float64(nsamp)
    return float(np.clip(mean, 1.0, cap))


# --------------------------------------------------- sorted-table utilities


def _merge_override(
    old_key: np.ndarray,
    new_key: np.ndarray,
    old_cols: Sequence[np.ndarray],
    new_cols: Sequence[np.ndarray],
):
    """Merge two sorted unique-key tables; ``new`` wins on key collisions.

    A linear two-way merge (searchsorted + masked scatter), not
    concat-and-argsort: the table is the O(distinct blocks) term of the
    streaming scorer's footprint and this runs once per chunk, so both the
    argsort transients and the n-log-n would otherwise dominate peak RSS
    and wall-clock on paper-scale graphs."""
    if len(old_key) == 0:
        return new_key, [np.asarray(c) for c in new_cols]
    if len(new_key) == 0:
        return old_key, list(old_cols)
    i = np.searchsorted(new_key, old_key)
    safe = np.minimum(i, len(new_key) - 1)
    dup = (i < len(new_key)) & (new_key[safe] == old_key)
    ok = old_key[~dup]
    at_new = np.zeros(len(ok) + len(new_key), dtype=bool)
    at_new[np.searchsorted(ok, new_key) + np.arange(len(new_key))] = True
    k = np.empty(len(at_new), dtype=np.result_type(old_key, new_key))
    k[at_new] = new_key
    k[~at_new] = ok
    cols = []
    for oc, nc in zip(old_cols, new_cols):
        c = np.empty(len(at_new), dtype=np.result_type(oc, nc))
        c[at_new] = nc
        c[~at_new] = oc[~dup]
        cols.append(c)
    return k, cols


def _last_per_key(keys: np.ndarray, cols: Sequence[np.ndarray]):
    """(unique sorted keys, last-occurrence value per key); rows are in
    occurrence order, so a stable sort keeps the last row last."""
    order = np.argsort(keys, kind="stable")
    k = keys[order]
    last = np.ones(len(k), dtype=bool)
    last[:-1] = k[:-1] != k[1:]
    return k[last], [c[order][last] for c in cols]


class BlockPosTable:
    """Per-block last position over a streamed (block, pos) event sequence.

    The streaming form of ``_no_future_demand``: after feeding every
    baseline demand L2 miss, ``has_later(b, p)`` answers "does block ``b``
    miss again strictly after position ``p``" — exactly the predicate the
    whole-trace packed-key searchsorted evaluates.
    """

    # Unseen-slot sentinel for the dense path: real positions are >= 0, so
    # the most negative int32 compares below every query position.
    _ABSENT = np.int32(-(2**31))
    # Dense slots are capped at 64 MiB of int32; a span beyond this (widely
    # scattered block ids) demotes the table to the sorted-row fallback.
    _MAX_SPAN = 1 << 24

    def __init__(self):
        # Dense path (default): trace addresses come from contiguous
        # page-aligned regions (apps.trace.TraceConfig), so block ids form
        # one dense span and a flat int32 array indexed by (block - lo)
        # updates by in-place scatter — no per-chunk merge transients.
        self._lo = 0
        self._dense = None
        # Sorted-row fallback for sparse id spans.
        self.blocks = np.zeros(0, dtype=np.int32)
        self.pos = np.zeros(0, dtype=np.int32)

    def __len__(self) -> int:
        if self._dense is not None:
            return int((self._dense != self._ABSENT).sum())
        return len(self.blocks)

    def update(self, blocks: np.ndarray, pos: np.ndarray) -> None:
        if len(blocks) == 0:
            return
        # Rows are stored as int32: the table is the O(distinct blocks)
        # footprint term, so per-row bytes matter. Block ids already must
        # fit in int32 (the cache engines assert it), and positions a
        # 2**31-access trace will never exceed.
        assert pos.min(initial=0) >= 0, "trace positions are non-negative"
        assert pos.max(initial=0) < 2**31, "trace position exceeds int32"
        assert blocks.max(initial=0) < 2**31, "block ids must fit in int32"
        ub, (up,) = _last_per_key(
            blocks.astype(np.int32), [pos.astype(np.int32)]
        )
        if self._dense is not None or len(self.blocks) == 0:
            lo, hi = int(ub[0]), int(ub[-1])
            if self._dense is not None:
                lo = min(lo, self._lo)
                hi = max(hi, self._lo + len(self._dense) - 1)
            if hi - lo + 1 <= self._MAX_SPAN:
                self._ensure_span(lo, hi)
                self._dense[ub.astype(np.int64) - self._lo] = up
                return
            self._demote()
        # Sparse fallback: overwrite existing keys in place (no
        # allocation), merge only genuinely new rows.
        n = len(self.blocks)
        if n:
            i = np.searchsorted(self.blocks, ub)
            safe = np.minimum(i, n - 1)
            hit = (i < n) & (self.blocks[safe] == ub)
            self.pos[i[hit]] = up[hit]
            if hit.all():
                return
            ub, up = ub[~hit], up[~hit]
        self.blocks, (self.pos,) = _merge_override(
            self.blocks, ub, [self.pos], [up]
        )

    def _ensure_span(self, lo: int, hi: int) -> None:
        """Grow the dense array to cover [lo, hi] (25% headroom on growth)."""
        if self._dense is None:
            self._lo = lo
            self._dense = np.full(hi - lo + 1, self._ABSENT, dtype=np.int32)
            return
        if lo >= self._lo and hi < self._lo + len(self._dense):
            return
        pad = max((hi - lo + 1) // 4, 1024)
        new_lo = lo if lo >= self._lo else max(lo - pad, 0)
        new_hi = hi if hi < self._lo + len(self._dense) else hi + pad
        if new_lo == self._lo and self._dense.base is None:
            # Right-only growth on an owned buffer: realloc in place
            # (glibc extends large blocks via mremap), so growth never
            # holds old + new copies resident at once.
            old_n = len(self._dense)
            self._dense.resize(new_hi - new_lo + 1, refcheck=False)
            self._dense[old_n:] = self._ABSENT
            return
        grown = np.full(new_hi - new_lo + 1, self._ABSENT, dtype=np.int32)
        grown[self._lo - new_lo : self._lo - new_lo + len(self._dense)] = (
            self._dense
        )
        self._lo, self._dense = new_lo, grown

    def _demote(self) -> None:
        """Convert dense content to sorted rows (sparse-span fallback)."""
        if self._dense is None:
            return
        idx = np.flatnonzero(self._dense != self._ABSENT)
        self.blocks = (idx + self._lo).astype(np.int32)
        self.pos = self._dense[idx]
        self._dense = None

    def has_later(self, qblocks: np.ndarray, qpos: np.ndarray) -> np.ndarray:
        if len(qblocks) == 0:
            return np.zeros(0, dtype=bool)
        if self._dense is not None:
            off = qblocks.astype(np.int64) - self._lo
            in_range = (off >= 0) & (off < len(self._dense))
            p = self._dense[np.clip(off, 0, len(self._dense) - 1)]
            # _ABSENT slots fail p > qpos for every valid (>= -2**30) qpos.
            return in_range & (p > qpos)
        if len(self.blocks) == 0:
            return np.zeros(len(qblocks), dtype=bool)
        # Match the table's int32 keys: a mixed-dtype searchsorted would
        # silently promote (copy) the whole table on every chunk.
        qb = qblocks.astype(np.int32)
        i = np.searchsorted(self.blocks, qb)
        safe = np.minimum(i, len(self.blocks) - 1)
        found = (i < len(self.blocks)) & (self.blocks[safe] == qb)
        return found & (self.pos[safe] > qpos)


# ------------------------------------------------ streaming classification


@dataclasses.dataclass
class ClassifyCarry:
    """Per-block residency state at a chunk seam.

    One row per block seen so far: the position (doubled units) and issuer
    of its last fill, whether every event since that fill was a prefetch
    (the ``all_pf_since_fill`` tail the next chunk resumes from), and
    whether the block's last event was a prefetch fill still awaiting its
    next same-block event (a *pending* early-eviction candidate, plus the
    selection bit it was issued under).
    """

    blocks: np.ndarray  # sorted int64
    fill_pos2: np.ndarray  # int32, doubled-position of last fill
    fill_issuer: np.ndarray  # int8 (issuer ids, -1 demand)
    all_pf_tail: np.ndarray  # bool
    pending: np.ndarray  # bool
    pending_sel: np.ndarray  # bool

    @classmethod
    def empty(cls) -> "ClassifyCarry":
        zb = np.zeros(0, dtype=bool)
        return cls(
            np.zeros(0, dtype=np.int64),
            np.zeros(0, dtype=np.int32),
            np.zeros(0, dtype=np.int8),
            zb,
            zb.copy(),
            zb.copy(),
        )


def classify_chunk(
    carry: ClassifyCarry,
    blocks: np.ndarray,
    is_pf: np.ndarray,
    pos2: np.ndarray,
    hit: np.ndarray,
    issuer: np.ndarray,
    fill_window2: int,
    t0: int,
    sel_issuer: int,
) -> Tuple[dict, ClassifyCarry]:
    """One chunk of merged (demand + prefetch) L2 events -> windowed counts.

    Mirrors :func:`~repro.memsim.scan_cache.classify_prefetch_events` with
    cross-chunk chains resumed from ``carry``.  Returns the count
    increments the metrics pipeline needs (so per-event arrays never
    accumulate) and the updated carry:

    - ``useful``:    demand hits on a prefetched line, in-window, filled by
                     ``sel_issuer``  (``evaluate``'s ``useful_mask``)
    - ``late_sel``:  those that were also late
    - ``late_any``:  late useful demand events of ANY issuer, in-window
                     (``_outcome_cycles``'s ``late``)
    - ``redundant_sel``/``early_sel``: prefetch events of ``sel_issuer``
                     issued in-window that were redundant / evicted early.

    Window membership uses the event's own undoubled position
    (``pos2 >> 1``), matching ``l2_pos >= t0`` / ``pf_pos >= t0``.
    """
    counts = dict(useful=0, late_sel=0, late_any=0, redundant_sel=0, early_sel=0)
    n = len(blocks)
    if n == 0:
        return counts, carry
    key = (blocks.astype(np.int64) << np.int64(31)) | np.arange(n, dtype=np.int64)
    order = np.argsort(key)
    b = blocks[order].astype(np.int64)
    p = pos2[order]
    f = is_pf[order]
    h = hit[order]
    iss = issuer[order].astype(np.int64)

    idx = np.arange(n, dtype=np.int64)
    chain_start = np.ones(n, dtype=bool)
    chain_start[1:] = b[1:] != b[:-1]
    chain_id = np.cumsum(chain_start) - 1
    chain_first = idx[chain_start][chain_id]

    # Carry lookup for this chunk's (strictly ascending) chain blocks.
    cb = b[chain_start]
    K = len(carry.blocks)
    if K:
        ci = np.searchsorted(carry.blocks, cb)
        safe = np.minimum(ci, K - 1)
        found = (ci < K) & (carry.blocks[safe] == cb)
        cf_pos2 = np.where(found, carry.fill_pos2[safe], np.int64(-1) << 50)
        cf_issuer = np.where(found, carry.fill_issuer[safe], np.int64(-9))
        cf_tail = np.where(found, carry.all_pf_tail[safe], False)
        cf_pend = np.where(found, carry.pending[safe], False)
        cf_psel = np.where(found, carry.pending_sel[safe], False)
    else:
        cf_pos2 = np.full(len(cb), np.int64(-1) << 50)
        cf_issuer = np.full(len(cb), np.int64(-9))
        cf_tail = np.zeros(len(cb), dtype=bool)
        cf_pend = np.zeros(len(cb), dtype=bool)
        cf_psel = np.zeros(len(cb), dtype=bool)

    # Last fill at/before each event; an event whose chain segment began in
    # an earlier chunk (no local fill yet) resumes from the carried fill.
    fill_idx = np.where(~h, idx, -1)
    last_fill = np.maximum.accumulate(fill_idx)
    carried_ev = last_fill < chain_first
    lf = np.maximum(last_fill, 0)

    cnp = np.cumsum((~f).astype(np.int64))
    cnp_before = cnp - (~f)
    local_all = (cnp - cnp_before[lf]) == 0  # all pf over [last_fill .. k]
    local_from_first = (cnp - cnp_before[chain_first]) == 0
    ev_tail = cf_tail[chain_id]
    all_pf_since_fill = np.where(
        carried_ev, ev_tail & local_from_first, local_all
    )
    prev_all_pf = np.zeros(n, dtype=bool)
    prev_all_pf[1:] = all_pf_since_fill[:-1]
    prev_all_pf[chain_start] = ev_tail[chain_start]

    fillpos2 = np.where(carried_ev, cf_pos2[chain_id], p[lf])
    fill_iss = np.where(carried_ev, cf_issuer[chain_id], iss[lf])

    useful = h & ~f & prev_all_pf
    late = useful & (fillpos2 + fill_window2 > p)
    redundant = f & h

    pos_ev = p >> 1
    in_win = pos_ev >= t0
    sel_pf_ev = f & in_win & (iss == sel_issuer)
    useful_sel = useful & in_win & (fill_iss == sel_issuer)
    counts["useful"] = int(useful_sel.sum())
    counts["late_sel"] = int((late & useful_sel).sum())
    counts["late_any"] = int((late & in_win).sum())
    counts["redundant_sel"] = int((redundant & sel_pf_ev).sum())

    # Early eviction resolved inside the chunk...
    next_is_miss = np.zeros(n, dtype=bool)
    next_is_miss[:-1] = ~h[1:] & ~chain_start[1:]
    early = (~h) & f & next_is_miss
    counts["early_sel"] = int((early & sel_pf_ev).sum())
    # ...and across the seam: a carried pending prefetch fill resolves at
    # its block's first event this chunk (miss == the line was evicted).
    resolved_early = chain_start & cf_pend[chain_id] & ~h
    counts["early_sel"] += int((resolved_early & cf_psel[chain_id]).sum())

    # New carry: the last event of every chain present in this chunk.
    last_in_chain = np.ones(n, dtype=bool)
    last_in_chain[:-1] = chain_start[1:]
    li = idx[last_in_chain]
    new_pending = (~h & f)[li]
    # Rows are stored packed (int32 pos2, int8 issuer, raw bools): the
    # carry persists for the whole run, so per-row bytes — not the chunk
    # math above, which stays int64 — set the resident footprint.  Live
    # rows always saw a real fill, so only dead rows (pruned below) can
    # hold the huge-negative not-found sentinel; clamping it to -2**30
    # keeps the int32 cast exact for every row that survives.
    assert p.max(initial=0) < 2**31, "doubled position exceeds int32"
    new_blocks, new_cols = cb, [
        np.maximum(fillpos2[li], np.int64(-(2**30))).astype(np.int32),
        fill_iss[li].astype(np.int8),
        all_pf_since_fill[li],
        new_pending,
        sel_pf_ev[li],
    ]
    mb, (m_pos2, m_iss, m_tail, m_pend, m_psel) = _merge_override(
        carry.blocks,
        new_blocks,
        [
            carry.fill_pos2,
            carry.fill_issuer,
            carry.all_pf_tail,
            carry.pending,
            carry.pending_sel,
        ],
        new_cols,
    )
    # Prune rows indistinguishable from absence: with tail and pending both
    # False the lookup above yields exactly the not-found defaults (tail
    # gates every read of fill_pos2/fill_issuer via prev_all_pf, pending
    # gates pending_sel), so only blocks with an outstanding prefetch stay
    # resident — the carry tracks the prefetched-not-yet-demanded set, not
    # every block the run ever touched.
    live = m_tail | m_pend
    if not live.all():
        mb = mb[live]
        m_pos2 = m_pos2[live]
        m_iss = m_iss[live]
        m_tail = m_tail[live]
        m_pend = m_pend[live]
        m_psel = m_psel[live]
    new_carry = ClassifyCarry(
        blocks=mb,
        fill_pos2=m_pos2,
        fill_issuer=m_iss,
        all_pf_tail=m_tail,
        pending=m_pend,
        pending_sel=m_psel,
    )
    return counts, new_carry


# ------------------------------------------------- composite run streaming


class CompositeRunScorer:
    """One composite (demand + prefetch) run scored chunk by chunk.

    ``feed`` consumes one chunk's demand L2 substream (global positions,
    ascending) plus the prefetch events triggered inside the chunk's access
    range, replicating ``simulate_with_prefetch``'s merge / L2 / classify /
    LLC pipeline with carried state; ``finalize`` reproduces
    ``metrics._outcome_cycles`` from the accumulated counts and spilled
    position streams.

    ``sel_issuer=None`` skips issuer-attributed counting (the baseline
    composite run only needs the window totals).  ``miss_sink`` optionally
    receives every demand L2 miss as ``(pos, block, iter)`` rows — the
    baseline-composite miss stream AMC trains on.
    """

    def __init__(
        self,
        cfg: HierarchyConfig,
        t0: int,
        spill_dir,
        tag: str,
        sel_issuer: Optional[int] = None,
        no_future: Optional[BlockPosTable] = None,
        miss_sink: Optional[SpillFile] = None,
    ):
        self.cfg = cfg
        self.t0 = t0
        self.sel = sel_issuer if sel_issuer is not None else -9
        self.count_issuer = sel_issuer is not None
        self.no_future = no_future
        self.miss_sink = miss_sink
        self.l2_state = init_state(cfg.l2.sets, cfg.l2.ways)
        self.llc_state = init_state(cfg.llc.sets, cfg.llc.ways)
        self.classify = ClassifyCarry.empty()
        # Blocks whose pending sel-issuer fill was evicted from L2: their
        # early eviction is certain but only counts if the block is ever
        # touched again (the classic path counts at the resolving event),
        # so just the block id waits here — sorted int32, one word per
        # wasted prefetch instead of a full carry row.
        self.evicted_pending = np.zeros(0, dtype=np.int32)
        d = Path(spill_dir)
        self.miss_spill = SpillFile(d / f"{tag}.misspos.i64")
        self.dram_spill = SpillFile(d / f"{tag}.drampos.i64")
        self.l2_misses = 0
        self.dram_demand = 0
        self.pf_dram = 0
        self.late_any = 0
        self.useful = 0
        self.late_sel = 0
        self.redundant = 0
        self.early = 0
        self.overpred = 0
        self.issued = 0

    def feed(
        self,
        d_pos: np.ndarray,
        d_blocks: np.ndarray,
        pf_blocks: np.ndarray,
        pf_pos: np.ndarray,
        pf_issuer: np.ndarray,
        d_iter: Optional[np.ndarray] = None,
    ) -> None:
        cfg = self.cfg
        nd = len(d_pos)
        npf = len(pf_pos)
        pf_blocks = np.asarray(pf_blocks, dtype=np.int64)
        pf_pos = np.asarray(pf_pos, dtype=np.int64)
        pf_issuer = np.asarray(pf_issuer, dtype=np.int8)
        if npf > 1:
            # Stable position sort: identity when already sorted, and the
            # same equal-position order (concat order) as the global path.
            o = np.argsort(pf_pos, kind="stable")
            pf_pos, pf_blocks, pf_issuer = pf_pos[o], pf_blocks[o], pf_issuer[o]

        total = nd + npf
        pf_slots = np.searchsorted(2 * d_pos, 2 * pf_pos + 1) + np.arange(npf)
        demand_slots = np.ones(total, dtype=bool)
        demand_slots[pf_slots] = False
        demand_slots = np.flatnonzero(demand_slots)
        mpos2 = np.empty(total, dtype=np.int64)
        mblocks = np.empty(total, dtype=np.int64)
        m_is_pf = np.zeros(total, dtype=bool)
        m_issuer = np.full(total, -1, dtype=np.int8)
        mpos2[demand_slots] = 2 * d_pos
        mpos2[pf_slots] = 2 * pf_pos + 1
        mblocks[demand_slots] = d_blocks
        mblocks[pf_slots] = pf_blocks
        m_is_pf[pf_slots] = True
        m_issuer[pf_slots] = pf_issuer

        # Settle deferred early evictions first: a block in evicted_pending
        # is absent from L2, so its first event this chunk is a guaranteed
        # miss — exactly the resolving event ``resolved_early`` counts.
        if self.count_issuer and len(self.evicted_pending):
            touched = np.isin(self.evicted_pending, mblocks.astype(np.int32))
            if touched.any():
                self.early += int(touched.sum())
                self.evicted_pending = self.evicted_pending[~touched]

        with _stage("cache_pass[l2]"):
            hit, self.l2_state = cache_pass(
                mblocks,
                cfg.l2.sets,
                cfg.l2.ways,
                state=self.l2_state,
                return_state=True,
            )
        cls_counts, self.classify = classify_chunk(
            self.classify,
            mblocks,
            m_is_pf,
            mpos2,
            hit,
            m_issuer,
            2 * cfg.pf_fill_window,
            self.t0,
            self.sel,
        )
        self.late_any += cls_counts["late_any"]
        if self.count_issuer:
            self.useful += cls_counts["useful"]
            self.late_sel += cls_counts["late_sel"]
            self.redundant += cls_counts["redundant_sel"]
            self.early += cls_counts["early_sel"]

        # Seam-time eviction pruning: a carry row whose block no longer
        # sits in the carried L2 state is nearly settled — the block's next
        # access is a guaranteed miss (only accesses insert lines), so
        # ``useful`` can never fire off the row and everything but a
        # sel-issuer pending bit reads back as the not-found defaults.
        # Dropping such rows (parking pending+sel ones as bare block ids in
        # ``evicted_pending``) is bit-identical to carrying them and caps
        # the carry at O(L2 capacity) instead of O(every block ever
        # prefetched).
        car = self.classify
        if len(car.blocks):
            tags = self.l2_state.tags
            cb32 = car.blocks.astype(np.int32)
            resident = (
                tags[cb32 & np.int32(cfg.l2.sets - 1)] == cb32[:, None]
            ).any(axis=1)
            if not resident.all():
                if self.count_issuer:
                    parked = cb32[car.pending & car.pending_sel & ~resident]
                    if len(parked):
                        self.evicted_pending = np.unique(
                            np.concatenate([self.evicted_pending, parked])
                        )
                self.classify = ClassifyCarry(
                    blocks=car.blocks[resident],
                    fill_pos2=car.fill_pos2[resident],
                    fill_issuer=car.fill_issuer[resident],
                    all_pf_tail=car.all_pf_tail[resident],
                    pending=car.pending[resident],
                    pending_sel=car.pending_sel[resident],
                )

        llc_sel = ~hit
        with _stage("cache_pass[llc]"):
            llc_hit, self.llc_state = cache_pass(
                mblocks[llc_sel],
                cfg.llc.sets,
                cfg.llc.ways,
                state=self.llc_state,
                return_state=True,
            )
        llc_is_pf = m_is_pf[llc_sel]
        llc_pos = mpos2[llc_sel] >> 1

        d_hit = hit[demand_slots]
        miss_pos = d_pos[~d_hit]
        in_win = miss_pos >= self.t0
        self.l2_misses += int(in_win.sum())
        self.miss_spill.append(miss_pos[in_win])
        d_llc_miss = ~llc_hit[~llc_is_pf]
        self.dram_demand += int((d_llc_miss & in_win).sum())
        dram_pos = miss_pos[d_llc_miss]
        self.dram_spill.append(dram_pos[dram_pos >= self.t0])
        pf_llc_pos = llc_pos[llc_is_pf]
        self.pf_dram += int(
            ((~llc_hit)[llc_is_pf] & (pf_llc_pos >= self.t0)).sum()
        )

        if self.count_issuer:
            sel_pf = (pf_pos >= self.t0) & (pf_issuer == self.sel)
            self.issued += int(sel_pf.sum())
            if self.no_future is not None:
                has_future = self.no_future.has_later(pf_blocks, pf_pos)
                self.overpred += int((sel_pf & ~has_future).sum())
        if self.miss_sink is not None:
            mi = (
                d_iter[~d_hit].astype(np.int64)
                if d_iter is not None
                else np.zeros(len(miss_pos), dtype=np.int64)
            )
            self.miss_sink.append(miss_pos, d_blocks[~d_hit], mi)

    def finalize(
        self,
        base: dict,
        dram_baseline: int,
        late_cost: float,
        meta_dram: int,
        tm: TimingModel,
    ) -> Tuple[float, dict]:
        """(cycles, counts) exactly as ``metrics._outcome_cycles`` returns."""
        empty = np.zeros(0, dtype=np.int64)
        mlp_llc = spilled_mlp(self.miss_spill, tm.mlp_window, tm.mlp_cap_llc)
        mlp_dram = spilled_mlp(self.dram_spill, tm.mlp_window, tm.mlp_cap_dram)
        dram_total = self.dram_demand + self.pf_dram + meta_dram
        cycles = estimate_cycles(
            num_accesses=base["accesses"],
            l1_misses=base["l1_miss"],
            l2_misses_demand=self.l2_misses,
            dram_demand=self.dram_demand,
            dram_total=dram_total,
            dram_baseline=dram_baseline,
            late_useful=self.late_any,
            l2_miss_pos=empty,
            dram_pos=empty,
            cfg=self.cfg,
            tm=tm,
            late_miss_cost=late_cost,
            mlp_llc=mlp_llc,
            mlp_dram=mlp_dram,
        )
        counts = dict(
            l2_misses=self.l2_misses,
            dram_demand=self.dram_demand,
            pf_dram=self.pf_dram,
            dram_total=dram_total,
            late=self.late_any,
        )
        self.miss_spill.close()
        self.dram_spill.close()
        return cycles, counts


def iter_grouped(
    spill: SpillFile, group_col: int, n_groups: int, rows: int = 1 << 20
) -> Iterator[Tuple[int, List[np.ndarray]]]:
    """Yield ``(group_id, columns)`` for ids ``0..n_groups-1`` in order.

    ``spill[:, group_col]`` must be nondecreasing (iteration-sorted spills
    are).  Groups with no rows yield empty columns, so callers see every
    group — the per-iteration AMC views include empty iterations exactly
    like the whole-trace path.
    """
    empties = [np.zeros(0, dtype=np.int64) for _ in range(spill.cols)]
    pending: Optional[List[np.ndarray]] = None
    cur = 0
    for chunk in spill.chunks(rows):
        cols = list(chunk) if spill.cols > 1 else [chunk]
        g = cols[group_col]
        while len(g):
            first = int(g[0])
            if first > cur:
                yield cur, pending if pending is not None else [c.copy() for c in empties]
                pending = None
                cur += 1
                continue
            end = int(np.searchsorted(g, cur, side="right"))
            take = [c[:end] for c in cols]
            pending = (
                take
                if pending is None
                else [np.concatenate([p, t]) for p, t in zip(pending, take)]
            )
            cols = [c[end:] for c in cols]
            g = cols[group_col]
            if len(g):  # rows for a later group follow: ``cur`` is complete
                yield cur, pending
                pending = None
                cur += 1
    if pending is not None:
        yield cur, pending
        cur += 1
    while cur < n_groups:
        yield cur, [c.copy() for c in empties]
        cur += 1


__all__ = [
    "BlockPosTable",
    "ClassifyCarry",
    "CompositeRunScorer",
    "SpillFile",
    "classify_chunk",
    "iter_grouped",
    "spilled_mlp",
]
