"""Fused multi-level hierarchy engine: one carried scan for L1+L2+LLC.

The per-level path (:func:`repro.memsim.hierarchy.simulate_demand`) runs
three separate set-parallel scans with host-side miss-substream compaction
between them — three kernel launches, two device→host→device round trips,
and three padded-matrix builds per trace.  On CPU the per-*step* scan
overhead dominates the per-step compute by orders of magnitude, so a pass
over the full stream costs roughly ``steps × overhead`` regardless of how
much state each step advances.  Fusing all levels into one machine keeps
the step count of the *L1 pass alone* (the full stream grouped at the
smallest set count) while retiring the L2 and LLC launches and both
compaction round trips entirely.

**Group decomposition.** Let ``G = min(sets_l)`` over the fused levels
(set counts are powers of two, so ``G`` divides each).  Group an access
``b`` by ``g = b & (G - 1)``.  Level ``l`` with ``R_l = sets_l / G``
relative sets per group maps ``b`` to set ``s_l = r_l * G + g`` where
``r_l = (b >> log2(G)) & (R_l - 1)`` — every group *exclusively owns*
``R_l`` whole sets at every level, so set independence (the equivalence
behind the set-parallel engine) holds per group for the entire hierarchy
at once.

**Run collapse.** Within one group's substream, a repeat of the
immediately preceding block is a guaranteed L1 hit: the block was just
filled (or refreshed) at that group's L1 set, and — because the group
exclusively owns whole sets at *every* level — nothing between the two
accesses can have touched that set.  :func:`_group_collapse` therefore
keeps only the first access of each run; the dropped repeats are emitted
as hit level 0 at unpack time without ever entering the scan.  The drop
is exact, not approximate: a repeat's only state effect is re-stamping
the MRU line's age, which leaves the per-set age *order* — all that
:func:`canonicalize_state` keeps, and all that LRU consults — unchanged.
Pointer-chasing graph traces are run-heavy (a third of the pgd/comdblp
demand stream), so the collapse typically halves the padded step count
outright.  The collapse is also the fused scan's *cost model*: a fused
step pays an inner-level gather/scatter a cascade step doesn't, so on
the host backend :func:`fused_cache_pass` runs the single scan only when
collapse shrank the pow2 bucket by at least two halvings, and otherwise
takes the bit-identical per-level cascade (short or run-light streams)
on the same fused-select machine.

**Carry layout.** Levels with ``R_l == 1`` ("outer": the group's lanes
are the set) carry dense ``(G, ways)`` tag/age arrays and update via a
fused one-hot select — no gather.  All ``R_l > 1`` levels ("inner") are
merged into a *single* ``(G, sum R_l, 2W)`` array of combined
``[tags | age]`` rows (``W`` = the widest inner ways; pad lanes are never
read), so each step issues exactly **one** gather and **one** scatter for
the whole inner hierarchy — the XLA-CPU cost of a step is dominated by
the number of gather/scatter rows it touches, not by how many levels
those rows advance.

**Bit identity.** The per-level way select is a single fused reduction,
``argmin(where(hitv, INT32_MIN, age))`` over the level's real lanes: at
most one lane can hit (tags are unique within a set), its ``INT32_MIN``
beats every age, and ages are pairwise distinct per set — so the winner
is unique and equals the reference's hit way on a hit and its LRU victim
on a miss, with no tie-break to preserve.
The age stamp is the global step counter: per *set* the stamp order
equals the access order, which is all :func:`canonicalize_state` keeps —
so carried states are bit-identical to the per-level engines', and fused
passes compose with them across shard seams.  A level only observes the
miss substream of the level above (updates are masked by ``alive``),
exactly the compacted substream of the per-level path.

**Batched dispatch.** Same-geometry streams (the per-prefetcher merged
scoring streams of one workload, seed-replica traces of one cell) pad to
a common bucket length and run under one ``vmap`` of the same scan — one
launch for the whole family instead of one per member.
"""
from __future__ import annotations

from functools import lru_cache
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.memsim.engine import (
    CacheState,
    _PAD_FACTOR,
    _PAD_FLOOR_CELLS,
    _bucket_len,
    cache_pass_fused_select,
    canonicalize_state,
    init_state,
)

Geometry = Tuple[Tuple[int, int], ...]  # ((sets, ways), ...) outer→inner

_AGE_PAD = np.iinfo(np.int32).max  # never argmin-selected (defensive: never read)
_TAG_PAD = -2  # never equals a block id >= 0 (defensive: never read)


def fused_group_count(levels: Sequence[Tuple[int, int]]) -> int:
    """G = min(sets): the group granularity of a fused pass."""
    return min(sets for sets, _ in levels)


def state_to_groups(tags_or_age: np.ndarray, groups: int) -> np.ndarray:
    """Reshape a ``(sets, ways)`` level array to ``(groups, R * ways)`` lanes.

    Set ``s = r * groups + g`` lands at ``[g, r * ways + w]``, so lane
    order is ``(relative set, way)`` — the order the kernel's masked
    argmin relies on for reference tie-breaking.
    """
    sets, ways = tags_or_age.shape
    r = sets // groups
    return (
        tags_or_age.reshape(r, groups, ways).transpose(1, 0, 2).reshape(groups, r * ways)
    )


def state_from_groups(lanes: np.ndarray, sets: int, ways: int) -> np.ndarray:
    """Inverse of :func:`state_to_groups`."""
    groups = lanes.shape[0]
    r = sets // groups
    return lanes.reshape(groups, r, ways).transpose(1, 0, 2).reshape(sets, ways)


@lru_cache(maxsize=32)
def _level_split(levels: Geometry):
    """Partition a geometry into outer (``R == 1``) and inner levels.

    Returns ``(inner, W, offs, sum_r)``: ``inner`` is ``(level index,
    R_l, ways)`` triples in level order, ``W`` the widest inner ways,
    ``offs`` each inner level's starting row in the merged carry, and
    ``sum_r`` the merged carry's total row count per group.
    """
    groups = fused_group_count(levels)
    inner = tuple(
        (i, sets // groups, ways)
        for i, (sets, ways) in enumerate(levels)
        if sets > groups
    )
    w_max = max((ways for _, _, ways in inner), default=0)
    offs, o = [], 0
    for _, r, _ in inner:
        offs.append(o)
        o += r
    return inner, w_max, tuple(offs), o


def _group_collapse(blocks: np.ndarray, groups: int):
    """Group the stream and drop run repeats (see *Run collapse* above).

    Returns ``(padded, order, keep, col, row, full_len)``: ``padded`` is
    the ``(max_len, groups)`` matrix of *kept* accesses (column prefixes
    in stream order, ``-1`` tail pads), ``order`` the stable group-by
    sort permutation over the full stream, ``keep`` the first-of-run mask
    over the sorted stream, ``padded[col, row]`` the kept accesses in
    sorted order, and ``full_len`` the bucket the *uncollapsed* stream
    would have padded to (the plan chooser compares the two buckets).
    Unpack per-access results with::

        sorted_res[keep] = res[col, row]   # dropped repeats: L1 hit (0)
        out[order] = sorted_res
    """
    blocks = np.asarray(blocks)
    # Same int32 guard as group_by_set: an id >= 2**31 would wrap negative
    # and alias the -1 pad sentinel.
    assert blocks.max(initial=0) < 2**31, "block ids must fit in int32"
    assert groups <= 1 << 16, "group index must fit the uint16 radix key"
    b32 = blocks.astype(np.int32)
    s = b32 & np.int32(groups - 1)
    # uint16 key → numpy's O(N) radix argsort (same permutation as the
    # int32 timsort path, ~4x faster); see group_by_set.
    order = np.argsort(s.astype(np.uint16), kind="stable")
    bs = b32[order]
    ss = s[order]
    keep = np.ones(len(b32), dtype=bool)
    if len(b32) > 1:
        keep[1:] = (bs[1:] != bs[:-1]) | (ss[1:] != ss[:-1])
    kept = bs[keep]
    row = ss[keep].astype(np.int64)
    counts = np.bincount(row, minlength=groups)
    max_len = _bucket_len(int(counts.max(initial=0)))
    full = np.bincount(ss, minlength=groups)
    full_len = _bucket_len(int(full.max(initial=0)))
    starts = np.zeros(groups, dtype=np.int64)
    np.cumsum(counts[:-1], out=starts[1:])
    col = np.arange(len(kept), dtype=np.int64) - np.repeat(starts, counts)
    padded = np.full((max_len, groups), -1, dtype=np.int32)
    padded[col, row] = kept
    return padded, order, keep, col, row, full_len


@lru_cache(maxsize=32)
def _fused_scan(levels: Geometry):
    """Jitted fused scan over grouped substreams for one geometry.

    Carry: ``(tags_o, age_o, …, merged?, t)`` — one dense ``(G, ways)``
    tag/age pair per outer level (in level order), then the merged
    ``(G, sum_r, 2W)`` inner carry when any level has ``R > 1``.  One
    step advances every group's next access through all levels with a
    single inner gather + scatter and emits its hit level (int8).
    """
    groups = fused_group_count(levels)
    lg = groups.bit_length() - 1
    k = len(levels)
    inner, w_max, offs, _ = _level_split(levels)
    gi = jnp.arange(groups)

    def step(carry, b):  # b: (groups,) int32, -1 = pad
        t = carry[-1]
        alive = b >= 0
        lvl = jnp.full(groups, k, dtype=jnp.int8)
        outs = list(carry[:-1])
        if inner:
            merged = outs[-1]
            # One gather for every inner level's accessed row.  Pads
            # (b == -1) read row ``offs`` and write it back unchanged
            # (the update is masked by ``alive``).
            idx = jnp.stack(
                [o + ((b >> lg) & (r - 1)) for (_, r, _), o in zip(inner, offs)],
                axis=1,
            )  # (groups, n_inner)
            rows = jnp.take_along_axis(merged, idx[:, :, None], axis=1)
        new_rows = []
        oj = ij = 0
        for i, (sets, ways) in enumerate(levels):
            if sets == groups:
                # Outer: the group's lanes *are* the set — no gather.
                row_t, row_a = outs[2 * oj], outs[2 * oj + 1]
            else:
                full = rows[:, ij]
                row_t = full[:, :ways]
                row_a = full[:, w_max : w_max + ways]
            hitv = row_t == b[:, None]
            hit = hitv.any(axis=1)
            # Fused victim select (one reduction, not argmax+argmin+where):
            # at most one hit lane per row, its INT32_MIN beats every age,
            # and ages are pairwise distinct per set — same unique winner.
            way = jnp.argmin(
                jnp.where(hitv, jnp.iinfo(jnp.int32).min, row_a), axis=1
            )
            onehot = (way[:, None] == jnp.arange(ways)[None, :]) & alive[:, None]
            nt = jnp.where(onehot, b[:, None], row_t)
            na = jnp.where(onehot, t, row_a)
            if sets == groups:
                outs[2 * oj] = nt
                outs[2 * oj + 1] = na
                oj += 1
            else:
                # Pad lanes ride through the scatter unchanged.
                new_rows.append(
                    jnp.concatenate(
                        [nt, full[:, ways:w_max], na, full[:, w_max + ways :]],
                        axis=1,
                    )
                )
                ij += 1
            lvl = jnp.where(alive & hit, jnp.int8(i), lvl)
            alive = alive & ~hit
        if inner:
            # One scatter for all inner levels; rows are disjoint by
            # construction (each level owns its ``offs`` range).
            outs[-1] = merged.at[gi[:, None], idx].set(jnp.stack(new_rows, axis=1))
        return tuple(outs) + (t + 1,), lvl

    @jax.jit
    def run(padded, *state):  # (max_len, groups) -> levels + final state
        init = tuple(state) + (jnp.int32(1),)
        final, lvls = jax.lax.scan(step, init, padded, unroll=4)
        return (lvls,) + final[:-1]

    return run


@lru_cache(maxsize=32)
def _fused_scan_batched(levels: Geometry):
    """The fused scan vmapped over a leading batch axis (one launch for a
    whole family of same-geometry streams)."""
    run = _fused_scan(levels)
    return jax.jit(jax.vmap(run))


def _resolve_states(
    levels: Sequence[Tuple[int, int]], states: Optional[Sequence[CacheState]]
) -> List[CacheState]:
    if states is None:
        return [init_state(s, w) for s, w in levels]
    assert len(states) == len(levels)
    return list(states)


def _grouped_state_args(states: Sequence[CacheState], groups: int):
    """Per-level ``(G, R*ways)`` lane pairs — the Pallas kernel's layout."""
    args = []
    for st in states:
        args.append(jnp.asarray(state_to_groups(st.tags, groups)))
        args.append(jnp.asarray(state_to_groups(st.age, groups)))
    return args


def _pack_state_args(states: Sequence[CacheState], levels: Geometry):
    """Pack per-level states into the host scan's carry layout."""
    groups = fused_group_count(levels)
    inner, w_max, offs, sum_r = _level_split(levels)
    args = []
    for (sets, ways), st in zip(levels, states):
        if sets == groups:
            args.append(jnp.asarray(state_to_groups(st.tags, groups)))
            args.append(jnp.asarray(state_to_groups(st.age, groups)))
    if inner:
        merged = np.full((groups, sum_r, 2 * w_max), _TAG_PAD, dtype=np.int32)
        merged[:, :, w_max:] = _AGE_PAD
        for (i, r, ways), o in zip(inner, offs):
            merged[:, o : o + r, :ways] = state_to_groups(
                states[i].tags, groups
            ).reshape(groups, r, ways)
            merged[:, o : o + r, w_max : w_max + ways] = state_to_groups(
                states[i].age, groups
            ).reshape(groups, r, ways)
        args.append(jnp.asarray(merged))
    return args


def _unpack_final_states(res, levels: Geometry) -> List[CacheState]:
    """Invert :func:`_pack_state_args` over a scan result and canonicalize.

    ``res`` is ``(lvls, *final_carry)``; batched callers pass one
    stream's slice.
    """
    groups = fused_group_count(levels)
    inner, w_max, offs, _ = _level_split(levels)
    finals: List[Optional[CacheState]] = [None] * len(levels)
    oi = 1
    for i, (sets, ways) in enumerate(levels):
        if sets == groups:
            tags = state_from_groups(np.asarray(res[oi]), sets, ways)
            age = state_from_groups(np.asarray(res[oi + 1]), sets, ways)
            finals[i] = canonicalize_state(tags, age)
            oi += 2
    if inner:
        merged = np.asarray(res[oi])
        for (i, r, ways), o in zip(inner, offs):
            sets = levels[i][0]
            tags = state_from_groups(
                merged[:, o : o + r, :ways].reshape(groups, r * ways), sets, ways
            )
            age = state_from_groups(
                merged[:, o : o + r, w_max : w_max + ways].reshape(groups, r * ways),
                sets,
                ways,
            )
            finals[i] = canonicalize_state(tags, age)
    return finals


def _skewed_padded(max_len: int, groups: int, stream_len: int) -> bool:
    """Padded-matrix blowup guard, evaluated on the *collapsed* matrix.

    Same budget as the per-level engine's: fall back when the padded
    cells exceed ``_PAD_FACTOR`` times the (original) stream length.
    Collapse only shrinks the matrix, so the fused path falls back
    strictly less often than a per-level pass over the same stream.
    """
    return max_len * groups > max(_PAD_FACTOR * stream_len, _PAD_FLOOR_CELLS)


def _fused_fallback(
    blocks: np.ndarray,
    levels: Sequence[Tuple[int, int]],
    states: List[CacheState],
    return_states: bool,
):
    """Per-level cascade on the fused-select machine (the plan-chooser
    and skew-guard path).

    Bit-identical to the fused scan by the engine contract: each level
    sees the miss substream of the level above, and canonical states
    compose across engines.  The passes run on
    :func:`~repro.memsim.engine.cache_pass_fused_select` — the same
    fused victim select as the scan — so the fused engine's cascade
    plan is itself faster than the frozen ``set_parallel`` comparator.
    """
    lvl = np.full(len(blocks), len(levels), dtype=np.int8)
    pos = np.arange(len(blocks), dtype=np.int64)
    sub = np.asarray(blocks)
    out_states = []
    for i, (sets, ways) in enumerate(levels):
        res = cache_pass_fused_select(sub, sets, ways, states[i], return_states)
        hit = res[0] if return_states else res
        if return_states:
            out_states.append(res[1])
        lvl[pos[hit]] = i
        pos = pos[~hit]
        sub = sub[~hit]
    if not return_states:
        return lvl
    return lvl, out_states


def _unpack_levels(
    n: int, lvls: np.ndarray, order, keep, col, row
) -> np.ndarray:
    """Scatter kept-access hit levels back to stream order; dropped run
    repeats are L1 hits (level 0) by construction."""
    sorted_lvl = np.zeros(n, dtype=np.int8)
    sorted_lvl[keep] = lvls[col, row]
    out = np.empty(n, dtype=np.int8)
    out[order] = sorted_lvl
    return out


def fused_cache_pass(
    blocks: np.ndarray,
    levels: Sequence[Tuple[int, int]],
    states: Optional[Sequence[CacheState]] = None,
    return_states: bool = False,
    use_pallas: Optional[bool] = None,
    force_scan: bool = False,
):
    """Run a stream through a fused K-level hierarchy in one carried scan.

    Returns the per-access **hit level** (int8: ``i`` = hit at
    ``levels[i]``, ``len(levels)`` = missed everywhere) and, with
    ``return_states=True``, the canonical per-level :class:`CacheState`
    carries — resumable by this or any per-level engine, bit-identically.
    ``use_pallas`` forces the Pallas kernel variant on or off (default:
    on when the backend is TPU).  ``force_scan`` bypasses the cost-based
    cascade fallback (the property tests use it to pin the carried-scan
    path on streams the plan chooser would route to the cascade); the
    skew guard still applies.
    """
    levels = tuple((int(s), int(w)) for s, w in levels)
    sts = _resolve_states(levels, states)
    if len(blocks) == 0:
        lvl = np.zeros(0, dtype=np.int8)
        if not return_states:
            return lvl
        return lvl, [CacheState(st.tags.copy(), st.age.copy()) for st in sts]
    groups = fused_group_count(levels)
    padded, order, keep, col, row, full_len = _group_collapse(blocks, groups)
    if _skewed_padded(padded.shape[0], groups, len(blocks)):
        return _fused_fallback(blocks, levels, sts, return_states)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas and not force_scan and padded.shape[0] * 4 > full_len:
        # Cost-based plan choice (host scan only; the Pallas kernel has
        # its own cost model on TPU): a fused step pays an inner-level
        # gather/scatter that a cascade step doesn't (~4x a first-level
        # cascade step on XLA-CPU), and the cascade's L2/LLC passes ride
        # on miss substreams far shorter than `full_len` — so the single
        # scan only wins its step-count bet when run collapse bought at
        # least two pow2 bucket halvings.  Short or run-light streams
        # take the bit-identical per-level cascade instead.
        return _fused_fallback(blocks, levels, sts, return_states)
    if use_pallas:
        from repro.kernels.cache_sim.fused_sim import fused_levels_pallas

        res = fused_levels_pallas(
            jnp.asarray(padded.T),
            levels,
            *_grouped_state_args(sts, groups),
            interpret=jax.default_backend() != "tpu",
        )
        lvls = np.asarray(res[0]).T
    else:
        res = _fused_scan(levels)(
            jnp.asarray(padded), *_pack_state_args(sts, levels)
        )
        lvls = np.asarray(res[0])
    out = _unpack_levels(len(blocks), lvls, order, keep, col, row)
    if not return_states:
        return out
    if use_pallas:
        finals = []
        for i, (sets, ways) in enumerate(levels):
            tags = state_from_groups(np.asarray(res[1 + 2 * i]), sets, ways)
            age = state_from_groups(np.asarray(res[2 + 2 * i]), sets, ways)
            finals.append(canonicalize_state(tags, age))
        return out, finals
    return out, _unpack_final_states(res, levels)


def fused_cache_pass_batch(
    streams: Sequence[np.ndarray],
    levels: Sequence[Tuple[int, int]],
    states: Optional[Sequence[Sequence[CacheState]]] = None,
    return_states: bool = False,
    force_scan: bool = False,
):
    """Batched fused pass over same-geometry streams: one vmapped launch.

    ``streams`` may differ in length; each is grouped (and run-collapsed)
    independently and padded to the family's common bucket length (pads
    are masked from every update and never gathered, so padding is exact,
    not approximate).  Returns one hit-level array per stream —
    bit-identical to looping :func:`fused_cache_pass` — plus per-stream
    canonical state lists with ``return_states=True``.  Streams that trip
    the set-skew guard (or an empty batch) fall back to the loop.
    """
    levels = tuple((int(s), int(w)) for s, w in levels)
    n = len(streams)
    sts = [
        _resolve_states(levels, None if states is None else states[i])
        for i in range(n)
    ]
    groups = fused_group_count(levels)
    grouped = (
        []
        if n == 0 or any(len(s) == 0 for s in streams)
        else [_group_collapse(s, groups) for s in streams]
    )
    if not grouped or any(
        _skewed_padded(g[0].shape[0], groups, len(s))
        for g, s in zip(grouped, streams)
    ) or (
        not force_scan
        and jax.default_backend() != "tpu"
        and any(g[0].shape[0] * 4 > g[5] for g in grouped)
    ):
        # Loop when any member is skewed or would not win as a fused
        # scan — each stream then makes its own plan choice.
        outs = [
            fused_cache_pass(
                streams[i], levels, sts[i], return_states,
                force_scan=force_scan,
            )
            for i in range(n)
        ]
        if not return_states:
            return outs
        return [o[0] for o in outs], [o[1] for o in outs]
    max_len = max(g[0].shape[0] for g in grouped)
    padded = np.full((n, max_len, groups), -1, dtype=np.int32)
    for i, g in enumerate(grouped):
        padded[i, : g[0].shape[0]] = g[0]
    per_stream = [_pack_state_args(s, levels) for s in sts]
    stacked = [
        jnp.asarray(np.stack([np.asarray(sa[j]) for sa in per_stream]))
        for j in range(len(per_stream[0]))
    ]
    res = _fused_scan_batched(levels)(jnp.asarray(padded), *stacked)
    lvls = np.asarray(res[0])
    outs = []
    for i, (_, order, keep, col, row, _full) in enumerate(grouped):
        outs.append(
            _unpack_levels(len(streams[i]), lvls[i], order, keep, col, row)
        )
    if not return_states:
        return outs
    final_states = [
        _unpack_final_states([np.asarray(r)[i] for r in res], levels)
        for i in range(n)
    ]
    return outs, final_states


def levels_to_hits(lvl: np.ndarray, k: int):
    """Unpack a hit-level array into the per-level hit masks of the
    cascaded path: mask ``i`` covers the miss substream of level ``i-1``
    (the full stream for ``i = 0``), exactly what
    :func:`~repro.memsim.hierarchy.simulate_demand` exposes."""
    masks = []
    sub = np.asarray(lvl)
    for i in range(k):
        hit = sub == i
        masks.append(hit)
        sub = sub[~hit]
    return masks


__all__ = [
    "fused_cache_pass",
    "fused_cache_pass_batch",
    "fused_group_count",
    "levels_to_hits",
    "state_from_groups",
    "state_to_groups",
]
