"""Calibrated miss-penalty IPC model (relative speedups, not cycle accuracy).

    cycles = exec_cycles
           + (L1 misses) * L2_lat
           + (L2 misses hitting LLC) * LLC_lat / MLP_llc
           + (DRAM accesses)          * DRAM_lat_eff / MLP_dram
           + (late useful prefetches) * DRAM_lat_eff * late_fraction

MLP is *measured* from miss clustering (average number of concurrent misses
within an MSHR-sized lookahead window, capped at the MSHR count), which is
how graph kernels actually extract memory-level parallelism on an OoO core.
Extra prefetch traffic raises effective DRAM latency through a bandwidth
queueing term — this is what penalizes the 958%-overtraffic prefetchers
(ISB) in the speedup plot exactly as in the paper.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.memsim.config import HierarchyConfig


@dataclasses.dataclass(frozen=True)
class TimingModel:
    cycles_per_access: float = 0.75  # core work per memory reference (4-wide)
    l2_hit_penalty: float = 3.0  # un-hidden L2 hit latency per L1 miss (OoO)
    mlp_window: int = 48  # accesses of lookahead for MLP measurement
    mlp_cap_llc: float = 8.0  # dependency-chain-limited overlap at LLC
    mlp_cap_dram: float = 6.0  # and at DRAM (1ch DDR4 bandwidth bound)
    late_fraction: float = 0.5  # fraction of avoided miss cost still paid
    bw_sensitivity: float = 0.12  # queueing: extra latency per 1x extra traffic


def measure_mlp(miss_pos: np.ndarray, window: int, cap: float) -> float:
    """Average number of misses in flight (clustering within ``window``).

    Subsamples above 1M misses — the estimate is a mean over miss sites.
    """
    if len(miss_pos) < 2:
        return 1.0
    pos = np.sort(miss_pos)
    sample = pos[:: max(len(pos) // 1_000_000, 1)]
    hi = np.searchsorted(pos, sample + window, side="right")
    lo = np.searchsorted(pos, sample, side="left")
    concurrent = hi - lo
    return float(np.clip(concurrent.mean(), 1.0, cap))


def estimate_cycles(
    num_accesses: int,
    l1_misses: int,
    l2_misses_demand: int,
    dram_demand: int,
    dram_total: int,
    dram_baseline: int,
    late_useful: int,
    l2_miss_pos: np.ndarray,
    dram_pos: np.ndarray,
    cfg: HierarchyConfig,
    tm: TimingModel = TimingModel(),
    late_miss_cost: float = 0.0,
    mlp_llc: float | None = None,
    mlp_dram: float | None = None,
) -> float:
    """``late_miss_cost``: average cost of the miss a late prefetch avoided,
    computed from the *baseline* run (a late prefetch can never be worse than
    the miss it replaced).  ``mlp_llc``/``mlp_dram`` accept precomputed MLP
    values (the streaming scorer measures them from spilled position streams
    with the exact :func:`measure_mlp` arithmetic) — ``None`` measures them
    from the in-memory position arrays as before."""
    if mlp_llc is None:
        mlp_llc = measure_mlp(l2_miss_pos, tm.mlp_window, tm.mlp_cap_llc)
    if mlp_dram is None:
        mlp_dram = measure_mlp(dram_pos, tm.mlp_window, tm.mlp_cap_dram)
    # Bandwidth queueing from extra (prefetch + metadata) DRAM traffic.
    extra_ratio = max(dram_total / max(dram_baseline, 1) - 1.0, 0.0)
    dram_eff = cfg.dram_latency * (1.0 + tm.bw_sensitivity * extra_ratio)

    exec_cycles = tm.cycles_per_access * num_accesses
    l2_cycles = tm.l2_hit_penalty * l1_misses
    llc_hits = max(l2_misses_demand - dram_demand, 0)
    llc_cycles = cfg.llc.latency * llc_hits / mlp_llc
    dram_cycles = dram_eff * dram_demand / mlp_dram
    late_cycles = tm.late_fraction * late_miss_cost * late_useful
    return exec_cycles + l2_cycles + llc_cycles + dram_cycles + late_cycles


def avg_miss_cost(
    l2_misses: int,
    dram_misses: int,
    l2_miss_pos: np.ndarray,
    dram_pos: np.ndarray,
    cfg: HierarchyConfig,
    tm: TimingModel = TimingModel(),
    mlp_llc: float | None = None,
    mlp_dram: float | None = None,
) -> float:
    """Average per-L2-miss stall cost of a run (used as the avoided cost)."""
    if l2_misses <= 0:
        return 0.0
    if mlp_llc is None:
        mlp_llc = measure_mlp(l2_miss_pos, tm.mlp_window, tm.mlp_cap_llc)
    if mlp_dram is None:
        mlp_dram = measure_mlp(dram_pos, tm.mlp_window, tm.mlp_cap_dram)
    llc_hits = max(l2_misses - dram_misses, 0)
    total = (
        cfg.llc.latency * llc_hits / mlp_llc
        + cfg.dram_latency * dram_misses / mlp_dram
    )
    return total / l2_misses
