"""Shared-LLC pass for multi-tenant serving (repro.serve).

K tenants run private L1/L2 hierarchies on their own substreams, but the
last-level cache is one physical resource: its eviction state is driven by
the *interleaved* miss stream of every tenant.  This module re-simulates
the per-tenant LLC-input event streams (captured by
``simulate_with_prefetch(..., keep_llc_stream=True)``) through a single
:func:`~repro.memsim.engine.cache_pass` over the globally merged stream.

Two invariants make the result both honest and regression-safe:

- **Namespace disjointness.**  Tenants are independent address spaces
  (every dataset is laid out from the same ``TraceConfig`` base), so
  tenant k's block ids are offset by ``k << shift``.  ``shift`` covers the
  largest block id *and* the LLC set-index width, so (a) tenants can never
  false-share a line and (b) each block keeps its private set index —
  contention changes LRU depth within a set, never the set mapping.

- **K=1 identity.**  With one tenant the offset is zero and the merge
  order is the identity, so the shared pass feeds ``cache_pass`` the exact
  private LLC stream — hit masks (and therefore every metric downstream)
  are bit-identical to the single-tenant path.  This is the serving
  subsystem's parity anchor, asserted in ``tests/test_serve.py``.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.memsim.engine import cache_pass


def tenant_shift(max_block: int, sets: int) -> int:
    """Offset exponent disambiguating tenant block namespaces.

    Covers the largest block id (disjointness) and the set-index width
    (``(k << shift) & (sets - 1) == 0``, so per-tenant set mapping is
    preserved — sets are powers of two throughout the simulator).
    """
    block_bits = int(max_block).bit_length()
    set_bits = int(sets - 1).bit_length() if sets > 1 else 0
    return max(block_bits, set_bits)


def shared_llc_pass(
    streams: Sequence[Tuple[np.ndarray, np.ndarray]], sets: int, ways: int
) -> List[np.ndarray]:
    """Simulate one shared LLC over K interleaved tenant streams.

    ``streams`` holds one ``(blocks, order_key)`` pair per tenant: the
    tenant's LLC-input block ids in its private simulation order, and a
    global ordering key per event (nondecreasing within a tenant; distinct
    tenants never tie — the serving interleaver derives keys from globally
    unique slot numbers).  Returns the per-tenant hit masks, each in the
    tenant's original event order.
    """
    total = sum(len(b) for b, _ in streams)
    if total == 0:
        return [np.zeros(0, dtype=bool) for _ in streams]
    max_block = max((int(b.max()) if len(b) else 0) for b, _ in streams)
    shift = tenant_shift(max_block, sets)
    top = ((len(streams) - 1) << shift) | max_block
    if top >= 2**31:
        raise ValueError(
            f"shared-LLC block namespace overflows int32: "
            f"{len(streams)} tenants x max block {max_block} needs "
            f"{top.bit_length()} bits"
        )
    blocks = np.concatenate(
        [b.astype(np.int64) + (k << shift) for k, (b, _) in enumerate(streams)]
    )
    keys = np.concatenate([k for _, k in streams])
    # Stable: within-tenant ties (several prefetches at one slot) keep
    # their private simulation order; cross-tenant keys never tie.
    order = np.argsort(keys, kind="stable")
    hits_merged = cache_pass(blocks[order], sets, ways)
    hits = np.empty(total, dtype=bool)
    hits[order] = hits_merged
    out, start = [], 0
    for b, _ in streams:
        out.append(hits[start : start + len(b)])
        start += len(b)
    return out


__all__ = ["shared_llc_pass", "tenant_shift"]
