"""Sharded checkpoint manager with two-phase atomic commit.

Layout per step::

    <dir>/step_000123.tmp/           (write phase)
        arrays.npz                   one entry per flattened leaf
        MANIFEST.json                tree structure + shapes + checksums
    <dir>/step_000123/               (rename = commit point)

Restart semantics: ``latest_step()`` scans committed directories only, so a
crash mid-write can never be resumed from (the .tmp dir is garbage-collected
on the next save). Checksums (crc32 per leaf) catch torn/corrupt files; a
corrupt checkpoint is skipped and the previous one used — together with the
launcher's retry loop this is the node-failure recovery path. Restore
accepts a *different* device mesh than the one that saved: arrays are
loaded on host then device_put against the new sharding (elastic rescale).
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Optional

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------ save ------------------------------

    def save(self, step: int, state: Any) -> str:
        tag = f"step_{step:09d}"
        tmp = os.path.join(self.dir, tag + ".tmp")
        final = os.path.join(self.dir, tag)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        flat, treedef = jax.tree_util.tree_flatten_with_path(state)
        arrays = {}
        manifest = {"step": step, "leaves": []}
        for i, (path, leaf) in enumerate(flat):
            key = f"leaf_{i}"
            arr = np.asarray(jax.device_get(leaf))
            logical_dtype = str(arr.dtype)
            if arr.dtype.kind == "V":  # bfloat16 etc: npz-safe uint view
                arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
            arrays[key] = arr
            manifest["leaves"].append(
                {
                    "key": key,
                    "path": jax.tree_util.keystr(path),
                    "shape": list(arr.shape),
                    "dtype": logical_dtype,
                    "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
                }
            )
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # commit point
        self._gc()
        return final

    # ----------------------------- restore ----------------------------

    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return max(steps) if steps else None

    def restore(
        self, state_like: Any, step: Optional[int] = None, shardings: Any = None
    ) -> tuple:
        """Restore into the structure of ``state_like``.

        Tries checkpoints newest-first; corrupt ones (bad checksum/missing
        leaf) are skipped — the node-failure recovery path."""
        candidates = (
            [step]
            if step is not None
            else sorted(
                {
                    int(n.split("_")[1])
                    for n in os.listdir(self.dir)
                    if n.startswith("step_") and not n.endswith(".tmp")
                },
                reverse=True,
            )
        )
        for s in candidates:
            try:
                return self._restore_one(state_like, s, shardings), s
            except Exception as e:  # noqa: BLE001
                print(f"[ckpt] step {s} unusable ({e}); trying older")
        raise FileNotFoundError(f"no usable checkpoint in {self.dir}")

    def _restore_one(self, state_like, step, shardings):
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        flat, treedef = jax.tree_util.tree_flatten(state_like)
        shard_flat = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings else [None] * len(flat)
        )
        assert len(manifest["leaves"]) == len(flat), "tree structure changed"
        out = []
        for leaf_info, like, shard in zip(manifest["leaves"], flat, shard_flat):
            arr = data[leaf_info["key"]]
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != leaf_info["crc32"]:
                raise IOError(f"checksum mismatch on {leaf_info['path']}")
            want = leaf_info["dtype"]
            if str(arr.dtype) != want:  # restore logical dtype (bf16 view)
                import ml_dtypes

                arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
            if shard is not None:
                out.append(jax.device_put(arr, shard))
            else:
                out.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def _gc(self):
        steps = sorted(
            {
                int(n.split("_")[1])
                for n in os.listdir(self.dir)
                if n.startswith("step_") and not n.endswith(".tmp")
            }
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True)
        for n in os.listdir(self.dir):
            if n.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, n), ignore_errors=True)
